"""Small AST helpers shared by the rules."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

__all__ = ["dotted_name", "call_name", "unwrap_transform", "const_int",
           "literal_int_tuple", "func_defs", "lambda_arity",
           "FunctionLike"]

FunctionLike = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def dotted_name(node: ast.AST) -> Optional[str]:
    """"jax.random.fold_in" for the matching attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def unwrap_transform(call: ast.Call) -> Tuple[Optional[str], ast.Call]:
    """Resolve ``jax.vmap(jax.random.X)(args)`` / ``partial(f, ...)``
    wrappers one level: returns (innermost dotted name, the call whose
    args are the data args). For a plain call returns (name, call)."""
    name = call_name(call)
    if name is not None:
        return name, call
    if isinstance(call.func, ast.Call):
        inner = call.func
        inner_name = call_name(inner)
        if inner_name in ("jax.vmap", "vmap", "jax.pmap", "functools.partial",
                          "partial", "jax.jit", "jit"):
            if inner.args:
                return dotted_name(inner.args[0]), call
    return None, call


def const_int(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = const_int(node.operand)
        return -v if v is not None else None
    return None


def literal_int_tuple(node: ast.AST) -> Optional[List[Optional[int]]]:
    """For a Tuple/List literal: each element's int value, or None for a
    non-literal element. None if the node is not a tuple/list at all."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    return [const_int(e) for e in node.elts]


def lambda_arity(node: ast.AST) -> Optional[int]:
    """Positional-arg count of a lambda / local def (None if unknown or
    it takes *args, which absorbs any grid arity)."""
    if not isinstance(node, FunctionLike):
        return None
    a = node.args
    if a.vararg is not None:
        return None
    return len(a.posonlyargs) + len(a.args)


def func_defs(tree: ast.AST) -> Iterator[ast.AST]:
    """Every def/lambda in the file, outermost first."""
    for node in ast.walk(tree):
        if isinstance(node, FunctionLike):
            yield node
