"""CLI: ``python -m repro.analysis <paths> [--format text|json|sarif]``.

Exit code 0 iff there are zero unsuppressed findings (and every file
parsed) — the CI ``lint`` job's pass condition.
"""
from __future__ import annotations

import argparse
import sys

from .core import RULES, run_analysis
from .output import RENDERERS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-native static analysis: RNG-stream discipline, "
                    "trace safety, Pallas kernel hygiene.")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--format", choices=sorted(RENDERERS),
                    default="text", help="output format (default: text)")
    ap.add_argument("--output", default=None,
                    help="write the report to this file instead of stdout")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    from . import rules as _rules  # noqa: F401  (register)
    if args.list_rules:
        for rid, rule in sorted(RULES.items()):
            print(f"{rid:24s} {rule.description}")
        return 0

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    report = run_analysis(args.paths or ["src"], rules=rules)
    rendered = RENDERERS[args.format](report)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(rendered + "\n")
        print(report.summary())
    else:
        print(rendered)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
