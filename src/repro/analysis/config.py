"""Per-file configuration: which rules run where.

Patterns are ``fnmatch`` globs matched against the file's posix path
relative to the analysis root (the CWD for the CLI). ``*`` crosses
``/`` in fnmatch, so ``tests/*`` covers the whole subtree.

``DEFAULT_CONFIG`` encodes the repo policy:

- ``rng-raw-prngkey`` sanctions the entry-point surfaces — tests,
  examples, benchmarks and ``repro.launch`` — where constructing a root
  ``PRNGKey`` is the point. Everything in the library proper must
  derive keys from a caller's stream (``ServeRequest.rng`` +
  ``fold_in``); the handful of intentional exceptions (the seed->key
  boundary in ``serving.request``, shape-only dummies for
  ``eval_shape``) carry inline justifications instead.
- ``host-sync-in-hot-path`` runs only where "hot path" is defined:
  the jitted round/step functions of ``serving/`` and ``sampling/``.
- ``refcount-pairing`` runs where refcounted pages live (``serving/``).
- ``pallas-block-align`` runs over ``src/`` only: interpret-mode tests
  deliberately use tiny unaligned pages/blocks to exercise rollback and
  deferral on small pools, which a compiled TPU run would reject but
  the interpreter accepts — shipping code must stay on the table.
- ``tests/analysis_fixtures/`` is the rule corpus: its *bad* snippets
  exist to violate the rules, so the default config excludes it
  everywhere (the analysis tests run it with an explicit config).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatch
from typing import Dict, Tuple

__all__ = ["RulePaths", "AnalysisConfig", "DEFAULT_CONFIG",
           "unrestricted_config"]


@dataclass(frozen=True)
class RulePaths:
    """Include/exclude globs for one rule. Empty include = everywhere."""

    include: Tuple[str, ...] = ()
    exclude: Tuple[str, ...] = ()

    def applies(self, path: str) -> bool:
        if self.include and not any(fnmatch(path, g) for g in self.include):
            return False
        return not any(fnmatch(path, g) for g in self.exclude)


@dataclass(frozen=True)
class AnalysisConfig:
    """Maps rule id -> path filter; unlisted rules run everywhere except
    ``global_exclude``."""

    rule_paths: Dict[str, RulePaths] = field(default_factory=dict)
    global_exclude: Tuple[str, ...] = ()
    #: methods that legitimately transfer page ownership instead of
    #: releasing (consumed by refcount-pairing)
    ownership_transfer_methods: Tuple[str, ...] = ("insert", "adopt",
                                                   "donate", "fork",
                                                   "transfer_slot")

    def applies(self, rule_id: str, path: str) -> bool:
        if any(fnmatch(path, g) for g in self.global_exclude):
            return False
        rp = self.rule_paths.get(rule_id)
        return rp.applies(path) if rp is not None else True


_ENTRY_POINTS = ("tests/*", "examples/*", "benchmarks/*",
                 "src/repro/launch/*")

DEFAULT_CONFIG = AnalysisConfig(
    rule_paths={
        "rng-raw-prngkey": RulePaths(exclude=_ENTRY_POINTS),
        "host-sync-in-hot-path": RulePaths(
            include=("src/repro/serving/*", "src/repro/sampling/*")),
        "refcount-pairing": RulePaths(include=("src/repro/serving/*",)),
        "pallas-block-align": RulePaths(include=("src/*",)),
    },
    global_exclude=("tests/analysis_fixtures/*",),
)


def unrestricted_config() -> AnalysisConfig:
    """Every rule everywhere — what the fixture tests run with."""
    return AnalysisConfig()
